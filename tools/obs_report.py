"""Observability tooling: validate traces, render metrics, run the smoke.

Three subcommands (stdlib-only unless ``--smoke`` spins up an engine):

  --check TRACE.json      Validate an exported Chrome/Perfetto trace:
                          parses as JSON, ``traceEvents`` is a list,
                          every event carries ``ph``/``ts``/``pid``/
                          ``tid``, complete ("X") events also carry
                          ``name``/``dur``.  Exits nonzero on any
                          violation — this is the CI gate behind
                          ``make obs-smoke``.
  --metrics M.json        Pretty-print a MetricsRegistry JSON export;
                          ``--prom`` re-renders it as Prometheus text
                          exposition instead.
  --smoke                 Serve a 6-request trace through a tiny traced
                          engine, export trace + metrics to /tmp,
                          self-validate the trace, and assert the
                          metric counters equal the engine ledgers and
                          the expected tracks are present.

Usage:
  python tools/obs_report.py --check /tmp/trace.json
  python tools/obs_report.py --metrics /tmp/metrics.json [--prom]
  PYTHONPATH=src python tools/obs_report.py --smoke
"""

from __future__ import annotations

import argparse
import json
import sys

REQUIRED_EVENT_KEYS = ("ph", "ts", "pid", "tid")


def check_trace(path: str) -> list[str]:
    """Schema errors in an exported trace file ([] = loadable)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"cannot load {path}: {e}"]
    errs = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return [f"traceEvents: want a list, got {type(events).__name__}"]
    if not events:
        errs.append("traceEvents: empty (nothing was traced?)")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"traceEvents[{i}]: not an object")
            continue
        for key in REQUIRED_EVENT_KEYS:
            if key not in ev:
                errs.append(f"traceEvents[{i}]: missing {key!r}")
        ph = ev.get("ph")
        if ph not in ("X", "M", "i", "I"):
            errs.append(f"traceEvents[{i}]: unknown ph {ph!r}")
        if ph == "X":
            if "name" not in ev:
                errs.append(f"traceEvents[{i}]: X event without name")
            if not isinstance(ev.get("dur"), (int, float)):
                errs.append(f"traceEvents[{i}]: X event without numeric dur")
            if isinstance(ev.get("ts"), (int, float)) and ev["ts"] < 0:
                errs.append(f"traceEvents[{i}]: negative ts {ev['ts']}")
    return errs


def _print_metrics(path: str, prom: bool) -> int:
    with open(path) as f:
        doc = json.load(f)
    series = doc.get("metrics")
    if not isinstance(series, list):
        print(f"{path}: no 'metrics' list")
        return 1
    if prom:
        # re-render the JSON export as Prometheus text by replaying it
        # into a fresh registry (keeps one authoritative formatter)
        from repro.obs import metrics as obs_metrics
        reg = obs_metrics.MetricsRegistry()
        for s in series:
            name, labels = s["name"], s.get("labels", {})
            if s["type"] == "counter":
                reg.counter(name, **labels).inc(s["value"])
            elif s["type"] == "gauge":
                reg.gauge(name, **labels).set(s["value"])
            else:
                h = reg.histogram(name, buckets=s["buckets"], **labels)
                h.counts = list(s["counts"])
                h.overflow = s["overflow"]
                h.total = s["count"]
                h.sum = s["sum"]
                h.min, h.max = s["min"], s["max"]
        print(reg.to_prometheus(), end="")
        return 0
    for s in series:
        lbl = ",".join(f"{k}={v}" for k, v in sorted(s.get(
            "labels", {}).items()))
        name = f"{s['name']}{{{lbl}}}" if lbl else s["name"]
        if s["type"] == "histogram":
            print(f"{name:44s} count={s['count']} sum={s['sum']:.3f} "
                  f"min={s['min']} max={s['max']}")
        else:
            print(f"{name:44s} {s['value']}")
    return 0


def run_smoke(trace_path: str, metrics_path: str) -> int:
    """6-request engine run with tracing on; validate everything after."""
    import numpy as np
    from repro.models import snn as snn_lib
    from repro.obs import trace as obs_trace
    from repro.serve.engine import EventRequest, SNNEventEngine

    import jax
    tracer = obs_trace.Tracer(enabled=True)
    prev = obs_trace.set_tracer(tracer)   # snn transfer spans need the global
    try:
        cfg = snn_lib.SNNConfig(n_in=32, n_hidden=16, n_classes=3,
                                n_steps=8, k=4)
        params = snn_lib.init_params(cfg, jax.random.PRNGKey(0))
        engine = SNNEventEngine(cfg, params, batch_slots=2, round_steps=4,
                                seed=7, tracer=tracer)
        rng = np.random.default_rng(0)
        reqs = [EventRequest(
            uid=i, priority=(1 if i == 4 else 0),
            events=(rng.random((int(rng.integers(6, 20)), 32)) < 0.25)
            .astype(np.float32))
            for i in range(6)]
        for r in reqs:
            engine.submit(r)
        engine.run(max_rounds=2)
        # force one preemption mid-serve so the trace shows the full
        # residency story: admit -> rounds -> preempt -> restore -> evict
        resident = next(r for r in engine._slot_req if r is not None)
        engine.preempt_request(resident.uid, backoff=False)
        engine.run()
        n_spans = tracer.export(trace_path)
        with open(metrics_path, "w") as f:
            json.dump(engine.metrics.to_dict(), f, indent=1)
    finally:
        obs_trace.set_tracer(prev)

    failures = []
    errs = check_trace(trace_path)
    if errs:
        failures += [f"trace: {e}" for e in errs]

    # expected tracks: scheduler phases, at least one slot lane, and the
    # checkpoint transfer lane from the forced preemption
    with open(trace_path) as f:
        doc = json.load(f)
    cats = {ev.get("cat") for ev in doc["traceEvents"] if ev.get("ph") == "X"}
    names = {ev.get("name") for ev in doc["traceEvents"]
             if ev.get("ph") == "X"}
    for want in ("scheduler", "slot00", "transfer"):
        if want not in cats:
            failures.append(f"trace: no spans on track {want!r}")
    for want in ("tick", "round", "admit", "evict", "checkpoint_save",
                 "checkpoint_restore"):
        if want not in names:
            failures.append(f"trace: no {want!r} span recorded")

    # counter / ledger consistency (the same invariant chaos asserts)
    m = engine.metrics
    checks = [
        ("terminal_total{state=completed}",
         m.value("terminal_total", state="completed"),
         len(engine.completed)),
        ("terminal_total{state=rejected}",
         m.value("terminal_total", state="rejected"), len(engine.rejected)),
        ("terminal_total{state=expired}",
         m.value("terminal_total", state="expired"), len(engine.expired)),
        ("preempted_total", m.value("preempted_total"),
         engine.preemption_count),
        ("completed requests", len(engine.completed), len(reqs)),
    ]
    for what, got, want in checks:
        if got != want:
            failures.append(f"metrics: {what} = {got}, want {want}")
    if m.histogram("round_ms").total == 0:
        failures.append("metrics: round_ms histogram is empty")

    if failures:
        print("[obs-smoke] FAIL")
        for msg in failures:
            print(f"  {msg}")
        return 1
    print(f"[obs-smoke] ok: {n_spans} spans -> {trace_path}, "
          f"{len(m.series())} metric series -> {metrics_path}, "
          f"counters == ledgers")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--check", metavar="TRACE.json",
                    help="validate an exported Perfetto trace file")
    ap.add_argument("--metrics", metavar="METRICS.json",
                    help="render a metrics JSON export")
    ap.add_argument("--prom", action="store_true",
                    help="with --metrics: Prometheus text format")
    ap.add_argument("--smoke", action="store_true",
                    help="run the traced 6-request engine smoke")
    ap.add_argument("--trace-out", default="/tmp/obs_smoke_trace.json",
                    help="smoke trace output path")
    ap.add_argument("--metrics-out", default="/tmp/obs_smoke_metrics.json",
                    help="smoke metrics output path")
    args = ap.parse_args(argv)

    if args.smoke:
        return run_smoke(args.trace_out, args.metrics_out)
    if args.check:
        errs = check_trace(args.check)
        if errs:
            print(f"{args.check}: INVALID")
            for e in errs:
                print(f"  {e}")
            return 1
        with open(args.check) as f:
            n = sum(1 for ev in json.load(f)["traceEvents"]
                    if ev.get("ph") == "X")
        print(f"{args.check}: ok ({n} spans)")
        return 0
    if args.metrics:
        return _print_metrics(args.metrics, args.prom)
    ap.print_help()
    return 2


if __name__ == "__main__":
    sys.exit(main())
