"""Regenerate the persistent tile-plan cache (PLAN_CACHE_fused_macro.json).

The CLI face of ``repro.tune``: autotune the canonical cells (or one cell
given explicitly) and persist the winners where
``kernels.fused_macro.plan_tiles`` will find them.  Porting to a new
backend is exactly one run of this on that backend — the cache is keyed on
device kind, so entries for other devices survive (``--no-merge`` to start
fresh).  See docs/TILE_PLANS.md for the cache contract.

Usage:
  PYTHONPATH=src python tools/tune_plans.py                    # make tune
  PYTHONPATH=src python tools/tune_plans.py --objective pj_per_sop
  PYTHONPATH=src python tools/tune_plans.py \\
      --cell 128x256x128x128x32 --density 0.05                 # one cell
  PYTHONPATH=src python tools/tune_plans.py --smoke \\
      --out /tmp/plan_cache.json                               # tune-smoke
"""

from __future__ import annotations

import argparse
import sys


def parse_cell(shape: str, density: float, mode: str, k: int):
    from repro.tune import autotune
    dims = [int(d) for d in shape.split("x")]
    if len(dims) != 5:
        raise SystemExit(f"--cell wants MxKxNCxNxT, got {shape!r}")
    return autotune.TuneCell(*dims, density=density, mode=mode, k=k)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--objective", default="ms",
                    choices=("ms", "pj_per_sop", "blend"),
                    help="what the winner minimizes: median latency, the "
                         "modeled kernel-energy proxy, or a geometric blend")
    ap.add_argument("--blend-weight", type=float, default=0.5,
                    help="blend objective: weight on pJ/SOP (0 = pure ms)")
    ap.add_argument("--iters", type=int, default=9,
                    help="timed calls per candidate (median taken)")
    ap.add_argument("--patience", type=int, default=None,
                    help="stop a cell after this many consecutive "
                         "non-improving candidates (default: measure all)")
    ap.add_argument("--cell", default=None, metavar="MxKxNCxNxT",
                    help="tune one launch shape instead of the canonical set")
    ap.add_argument("--density", type=float, default=0.05,
                    help="event density for --cell (default 0.05)")
    ap.add_argument("--mode", default="kwn", choices=("kwn",),
                    help="macro mode for --cell")
    ap.add_argument("--k", type=int, default=None,
                    help="KWN winner count for --cell (default: bench K)")
    ap.add_argument("--out", default=None,
                    help="cache file to write (default: repo-root "
                         "PLAN_CACHE_fused_macro.json, or "
                         "$REPRO_PLAN_CACHE_PATH)")
    ap.add_argument("--no-merge", action="store_true",
                    help="drop existing cache entries instead of merging")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: one tiny cell, 2 timed iters, then "
                         "assert the written cache round-trips to a lookup "
                         "hit that plan_tiles consumes")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export a Perfetto trace of the tuning run (one "
                         "span per candidate measurement)")
    args = ap.parse_args(argv)

    if args.trace_out:
        from repro.obs import trace as obs_trace
        obs_trace.set_tracer(obs_trace.Tracer(enabled=True))

    from repro.tune import autotune, cache

    if args.smoke:
        cells = (autotune.TuneCell(16, 128, 128, 128, 4, 0.05),)
        entries, path = autotune.tune(
            cells, objective=args.objective, iters=2,
            path=args.out, merge=not args.no_merge)
        cache.clear_memo()
        cell = cells[0]
        hit = cache.lookup(cell.m, cell.k_dim, cell.nc, cell.n, cell.t,
                           mode=cell.mode, density=cell.density, path=path)
        assert hit is not None, "smoke: written cache did not round-trip"
        from repro.kernels import fused_macro
        import os
        os.environ[cache.ENV_PATH] = path
        cache.clear_memo()
        plan = fused_macro.plan_tiles(cell.m, cell.k_dim, cell.nc, cell.n,
                                      cell.t, mode=cell.mode)
        assert (plan.bm, plan.bk, plan.bn) == tuple(hit), \
            f"smoke: plan_tiles {plan[:3]} != cached {tuple(hit)}"
        print(f"tune-smoke OK: {len(entries)} entries, round-trip hit "
              f"{tuple(hit)} @ {path}")
        _export_trace(args.trace_out)
        return 0

    if args.cell:
        k = args.k if args.k is not None else autotune.K_WIN
        cells = (parse_cell(args.cell, args.density, args.mode, k),)
    else:
        cells = autotune.CANONICAL_CELLS
    autotune.tune(cells, objective=args.objective,
                  blend_weight=args.blend_weight, iters=args.iters,
                  patience=args.patience, path=args.out,
                  merge=not args.no_merge)
    _export_trace(args.trace_out)
    return 0


def _export_trace(path: str | None) -> None:
    if not path:
        return
    from repro.obs import trace as obs_trace
    n = obs_trace.get_tracer().export(path)
    print(f"wrote {n} spans to {path}")


if __name__ == "__main__":
    sys.exit(main())
